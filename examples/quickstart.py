"""Quickstart: train a CatBoost-style GBDT in JAX, predict with the
vectorized pipeline, verify against the scalar reference.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import boosting, losses, predict
from repro.core.boosting import BoostingParams
from repro.data import synthetic


def main():
    # Covertype-shaped synthetic data (54 features, 7 classes)
    ds = synthetic.load("covertype", scale=0.01)
    loss = losses.make_loss("multiclass", n_classes=7)
    params = BoostingParams(n_trees=80, depth=6, learning_rate=0.4)

    print(f"training on {ds.x_train.shape} ...")
    ens, hist = boosting.fit(ds.x_train, ds.y_train, loss=loss,
                             params=params)
    print(f"ensemble: {ens.describe()}")
    print(f"final train loss {hist['train_loss'][-1]:.4f} "
          f"metric {hist['final_metric']:.4f}")

    x_test = jnp.asarray(ds.x_test)
    pred = predict.predict_class(ens, x_test)
    acc = float((np.asarray(pred) == ds.y_test).mean())
    print(f"test accuracy: {acc:.4f}")

    # strategies must agree (paper's x86-vs-RISC-V parity check analog)
    staged = predict.raw_predict(ens, x_test[:64], strategy="staged",
                                 backend="ref")
    fused = predict.raw_predict(ens, x_test[:64], strategy="fused",
                                backend="ref")
    err = float(jnp.max(jnp.abs(staged - fused)))
    print(f"staged vs fused max deviation: {err:.2e}  "
          f"({'OK' if err < 1e-4 else 'MISMATCH'})")


if __name__ == "__main__":
    main()
